import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")


def run_multidevice(code: str, n_devices: int = 8, timeout: int = 600) -> str:
    """Run a python snippet in a subprocess with forced host device count.

    Multi-device shard_map tests must not pollute this process's jax device
    state (smoke tests see 1 device per the assignment), hence subprocess.
    """
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = SRC
    r = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True, timeout=timeout, env=env
    )
    if r.returncode != 0:
        raise AssertionError(f"subprocess failed:\nSTDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}")
    return r.stdout


@pytest.fixture(scope="session")
def multidevice():
    return run_multidevice
