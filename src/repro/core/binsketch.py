"""BinSketch (Pratap, Bera, Revanuru 2019) — core sketching primitives.

Definition 4: a random map ``pi: [d] -> [N]``; ``a_s[j] = OR_{i: pi(i)=j} a[i]``.

Two mapping modes:
  * ``table``: ``pi`` is an explicit ``(d,)`` int32 array (exact, O(d log N)
    random bits as in the paper's Table I).
  * ``hash``: multiply-shift hash evaluated on the fly — for d too large to
    materialize a table (the paper's tera-scale motivation). Slight modulo
    bias for N not a power of two; negligible for N << 2^32.

Sketches are returned *packed* (uint32 words, see ``repro.core.packed``).
All functions are jit-friendly; randomness is jax.random-keyed.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional

import jax
import jax.numpy as jnp

from . import packed as pk

__all__ = [
    "BinSketchConfig",
    "theorem1_N",
    "make_mapping",
    "map_indices",
    "sketch_dense",
    "sketch_indices",
    "sketch_indices_dense",
]


def theorem1_N(psi: int, rho: float = 0.1) -> int:
    """Sketch length from Theorem 1: ``N = psi * sqrt((psi / 2) * ln(2 / rho))``."""
    if psi < 1:
        raise ValueError(f"sparsity psi must be >= 1, got {psi}")
    if not 0.0 < rho < 1.0:
        raise ValueError(f"failure probability rho must be in (0, 1), got {rho}")
    return int(math.ceil(psi * math.sqrt(psi / 2.0 * math.log(2.0 / rho))))


@dataclasses.dataclass(frozen=True)
class BinSketchConfig:
    """Static configuration of one BinSketch instance."""

    d: int  # original dimension
    n_bins: int  # sketch length N
    mode: str = "table"  # "table" | "hash"

    def __post_init__(self):
        if self.mode not in ("table", "hash"):
            raise ValueError(f"unknown mode {self.mode!r}")
        if self.n_bins < 1:
            raise ValueError("n_bins must be >= 1")

    @property
    def n_words(self) -> int:
        return pk.num_words(self.n_bins)

    @staticmethod
    def from_sparsity(d: int, psi: int, rho: float = 0.1, mode: str = "table") -> "BinSketchConfig":
        return BinSketchConfig(d=d, n_bins=theorem1_N(psi, rho), mode=mode)


def make_mapping(cfg: BinSketchConfig, key: jax.Array) -> jax.Array:
    """Materialize the random map pi.

    ``table`` mode: ``(d,)`` int32 of uniform bins.
    ``hash`` mode: ``(2,)`` uint32 multiply-shift coefficients ``(a|1, b)``.
    """
    if cfg.mode == "table":
        return jax.random.randint(key, (cfg.d,), 0, cfg.n_bins, dtype=jnp.int32)
    coeffs = jax.random.bits(key, (2,), dtype=jnp.uint32)
    # odd multiplier makes the multiply-shift family 2-universal enough here
    return coeffs.at[0].set(coeffs[0] | jnp.uint32(1))


def map_indices(cfg: BinSketchConfig, mapping: jax.Array, idx: jax.Array) -> jax.Array:
    """pi(idx) for int32 index arrays; negative indices (padding) pass through as -1."""
    valid = idx >= 0
    safe = jnp.where(valid, idx, 0)
    if cfg.mode == "table":
        bins = mapping[safe]
    else:
        a, b = mapping[0], mapping[1]
        h = a * safe.astype(jnp.uint32) + b  # wraps mod 2^32
        bins = (h % jnp.uint32(cfg.n_bins)).astype(jnp.int32)
    return jnp.where(valid, bins, -1)


def sketch_dense(cfg: BinSketchConfig, mapping: jax.Array, x: jax.Array) -> jax.Array:
    """Sketch dense binary rows ``x: (B, d)`` -> packed ``(B, W)`` uint32.

    OR-aggregation per bin == segment-max over {0,1}.
    """
    if cfg.mode != "table":
        raise ValueError("sketch_dense requires table mode (dense d implies materializable d)")
    seg = jax.ops.segment_max(
        x.astype(jnp.uint8).T, mapping, num_segments=cfg.n_bins, indices_are_sorted=False
    )  # (N, B)
    return pk.pack_bits(seg.T)


def sketch_indices_dense(cfg: BinSketchConfig, mapping: jax.Array, idx: jax.Array) -> jax.Array:
    """Sketch padded sparse rows ``idx: (B, P)`` (pad = -1) -> dense ``(B, N)`` uint8.

    Scatter-max construction — the pure-JAX reference path. The TPU-native
    compare-reduce construction lives in ``repro.kernels.sketch_build``.
    """
    bsz = idx.shape[0]
    bins = map_indices(cfg, mapping, idx)
    valid = (bins >= 0).astype(jnp.uint8)
    safe = jnp.where(bins >= 0, bins, 0)
    rows = jnp.broadcast_to(jnp.arange(bsz)[:, None], idx.shape)
    dense = jnp.zeros((bsz, cfg.n_bins), jnp.uint8)
    return dense.at[rows, safe].max(valid)


def sketch_indices(cfg: BinSketchConfig, mapping: jax.Array, idx: jax.Array) -> jax.Array:
    """Sketch padded sparse rows ``idx: (B, P)`` -> packed ``(B, W)`` uint32."""
    return pk.pack_bits(sketch_indices_dense(cfg, mapping, idx))
